"""Device-resident client batch cache: plan/apply correctness against the
host-only packer, LRU/eviction accounting, and engine integration (hit-rate
under skewed sampling, bit-identical training with the cache on or off)."""

import jax
import numpy as np

from repro.core import (EngineConfig, FederatedEngine, SyntheticTelemetry,
                        ZipfSampler, make_placement, s_bucket)
from repro.core.placement import Assignment, ClientInfo, WorkerInfo
from repro.data import make_federated_dataset
from repro.data.batching import (PackBuffers, build_round_arrays,
                                 gather_content_rows, plan_round)
from repro.data.device_cache import DeviceBatchCache
from repro.distributed import WorkerPool
from repro.models.papertasks import make_task_model
from repro.optim import sgd


def _assignment(ds, cids, workers=2):
    winfos = [WorkerInfo(wid=i) for i in range(workers)]
    per = {w.wid: [] for w in winfos}
    for i, c in enumerate(cids):
        per[winfos[i % workers].wid].append(
            ClientInfo(cid=c, n_batches=ds.n_batches(c),
                       n_samples=ds.n_samples(c)))
    return Assignment(per_worker=per), winfos


def _ds():
    return make_federated_dataset("sr", n_clients=32, input_dim=8,
                                  batch_size=2, size_mu=2.0, size_sigma=0.5)


def _device_round(ds, cids, cache, t, *, steps_cap=3, buffers=None,
                  with_ref=True):
    """One cache-mediated round: plan → gather compact miss rows → fused
    device assembly.  Returns (assembled device batches, cache plan,
    reference full pack).  NOTE: the returned batches double as the cache's
    persistent round base and are donated by the NEXT same-shape round —
    read them before driving another round."""
    assignment, workers = _assignment(ds, cids)
    plan = plan_round(assignment, workers, steps_cap=steps_cap)
    S = s_bucket(plan.s_real)
    cplan = cache.plan(plan, S, t)
    rows = gather_content_rows(ds, plan, cplan.content_mask,
                               cplan.n_miss_rows, batch_size=2,
                               buffers=buffers)
    ref = (build_round_arrays(ds, plan=plan, batch_size=2, s_align=s_bucket)
           if with_ref else None)
    miss = {k: jax.device_put(v) for k, v in rows.items()}
    out = cache.apply(miss, cplan)
    return out, cplan, ref


def _assert_matches_ref(out, ref):
    mask = ref.step_mask.astype(bool)
    for name in ref.batches:
        got = np.asarray(out[name])
        np.testing.assert_array_equal(got[mask], ref.batches[name][mask])


def test_cache_round_trip_bit_identical_to_host_pack():
    """Round 2 re-samples round 1's clients: every slot the cache assembles
    device-side must hold exactly the bytes the host path would have packed
    (real slots — padded slots are masked and may differ)."""
    ds = _ds()
    cache = DeviceBatchCache(64)
    out1, cp1, ref1 = _device_round(ds, [1, 2, 3, 4], cache, t=0)
    assert cp1.hit_steps == 0 and cp1.inserted_clients == 4
    _assert_matches_ref(out1, ref1)       # before round 2 donates the base
    out2, cp2, ref2 = _device_round(ds, [3, 4, 5, 1], cache, t=1)
    assert cp2.hit_clients == 3 and cp2.miss_clients == 1
    assert cp2.hit_steps > 0
    _assert_matches_ref(out2, ref2)


def test_cache_hit_skips_host_gather():
    """On a full-hit round the packer is asked for zero batches of content —
    not even the leaf-shape probe (PackBuffers remembers the row specs)."""
    ds = _ds()
    cache = DeviceBatchCache(64)
    buffers = PackBuffers(depth=2)
    _device_round(ds, [1, 2], cache, t=0, buffers=buffers)
    calls = []
    orig = ds.gather_batches

    def spy(cids, bidx, **kw):
        calls.append(len(np.asarray(cids)))
        return orig(cids, bidx, **kw)

    ds.gather_batches = spy
    _, cp, _ = _device_round(ds, [1, 2], cache, t=1, buffers=buffers,
                             with_ref=False)
    assert cp.miss_steps == 0 and cp.content_mask is not None
    assert not cp.content_mask.any()
    assert calls == []                  # no host gather at all
    assert cp.n_miss_rows == 1          # H2D shrinks to one padding row
    assert cp.bytes_saved > 0


def test_lru_eviction_accounting():
    """Capacity forces the least-recent client out; counters add up and the
    evicted client misses (and re-inserts) when it returns."""
    ds = _ds()
    nb = {c: min(ds.n_batches(c), 3) for c in range(8)}
    cap = nb[1] + nb[2] + nb[3]
    cache = DeviceBatchCache(cap)
    _device_round(ds, [1, 2, 3], cache, t=0)       # fills the pool exactly
    assert cache.rows_used == cap and cache.clients_cached == 3
    # 4 needs rows → evicts LRU head (client 1); 2 and 3 untouched until now
    _, cp, _ = _device_round(ds, [2, 3, 4], cache, t=1)
    assert cp.hit_clients == 2 and cp.inserted_clients == 1
    assert cp.evicted_clients >= 1
    assert cache.clients_cached == 3
    # client 1 was evicted: must miss now, and something else gets evicted
    _, cp, _ = _device_round(ds, [1], cache, t=2)
    assert cp.hit_clients == 0 and cp.miss_clients == 1
    st = cache.stats()
    assert st["insertions"] - st["evictions"] == cache.clients_cached
    assert st["hit_steps"] + st["miss_steps"] > 0
    assert 0.0 < st["hit_rate"] < 1.0
    assert cache.rows_used <= cap


def test_same_round_entries_never_evicted():
    """When every resident row was touched this round, insertion is skipped
    rather than evicting a row the current round's scatter still needs."""
    ds = _ds()
    nb1 = min(ds.n_batches(1), 3)
    cache = DeviceBatchCache(nb1)                  # room for one client
    _, cp, _ = _device_round(ds, [1, 2], cache, t=0)
    assert cp.inserted_clients == 1                # only client 1 fit
    _, cp, _ = _device_round(ds, [1, 2], cache, t=1)
    assert cp.hit_clients == 1                     # 1 hits …
    assert cp.inserted_clients == 0                # … and 2 cannot displace it
    assert cp.evicted_clients == 0


def test_nb_mismatch_reinsert_frees_old_rows():
    """A client re-inserted under a different steps_cap must release its
    superseded rows — otherwise pool capacity leaks on every mismatch."""
    ds = _ds()
    cache = DeviceBatchCache(64)
    _device_round(ds, [1, 2], cache, t=0, steps_cap=3)
    used_before = cache.rows_used
    for t in range(1, 4):  # alternate nb: each re-insert supersedes the old
        _, cp, _ = _device_round(ds, [1, 2], cache, t=t,
                                 steps_cap=2 if t % 2 else 3)
        assert cp.hit_clients == 0          # nb mismatch is always a miss
        assert cp.inserted_clients == 2
    assert cache.rows_used <= used_before   # no monotonic leak
    st = cache.stats()
    assert st["insertions"] - st["evictions"] == cache.clients_cached


def test_invalidate_clears_entries_and_recovers():
    """invalidate() drops every entry; the next round misses, re-inserts,
    and still assembles bit-identical content."""
    ds = _ds()
    cache = DeviceBatchCache(64)
    _device_round(ds, [1, 2], cache, t=0)
    assert cache.clients_cached == 2
    cache.invalidate()
    assert cache.clients_cached == 0 and cache.rows_used == 0
    out, cp, ref = _device_round(ds, [1, 2], cache, t=1)
    assert cp.hit_clients == 0 and cp.inserted_clients == 2
    _assert_matches_ref(out, ref)


def test_oversized_client_never_cached():
    ds = _ds()
    cache = DeviceBatchCache(2)
    _, cp, _ = _device_round(ds, [1], cache, t=0, steps_cap=5)
    if min(ds.n_batches(1), 5) > 2:
        assert cp.inserted_clients == 0 and cache.clients_cached == 0


def _engine(depth, cache_rows, *, placement="rr", sampler=None,
            cache_bytes=0):
    ds = make_federated_dataset("sr", n_clients=64, input_dim=16,
                                batch_size=4, size_mu=2.5, size_sigma=0.8)
    params, loss = make_task_model("sr", jax.random.key(0), input_dim=16,
                                   width=32, n_blocks=2)
    return FederatedEngine(
        dataset=ds, loss_fn=loss, init_params=params,
        optimizer=sgd(0.1, momentum=0.9),
        placement=make_placement(placement),
        sampler=sampler or ZipfSampler(64, 8, a=1.2),
        pool=WorkerPool.homogeneous(2, type_name="a40", concurrency=2),
        telemetry=SyntheticTelemetry(),
        config=EngineConfig(steps_cap=4, batch_size=4,
                            pipeline_depth=depth,
                            device_cache_batches=cache_rows,
                            device_cache_bytes=cache_bytes))


def test_engine_cache_bit_identical_and_hits_under_skew():
    """Zipf sampling re-draws hot clients: the cached engine must train
    bit-identically to the uncached one while reporting hits and bytes
    saved in RoundResult."""
    plain = _engine(0, 0).run(8)
    for depth in (0, 2):
        eng = _engine(depth, 64)
        res = eng.run(8)
        assert [r.loss for r in res] == [r.loss for r in plain], depth
        assert sum(r.cache_hit_rate for r in res) > 0
        assert sum(r.cache_bytes_saved for r in res) > 0
        assert all(0.0 <= r.cache_hit_rate <= 1.0 for r in res)
        st = eng.cache_stats
        assert st["hit_steps"] > 0 and st["rounds"] == 8
        assert st["bytes_saved"] == sum(r.cache_bytes_saved for r in res)


def test_engine_cache_accounting_under_eviction():
    """A pool much smaller than the working set must keep evicting yet stay
    exact: counters consistent, training unchanged."""
    plain = _engine(0, 0).run(8)
    eng = _engine(1, 12)                  # a few clients' worth of rows
    res = eng.run(8)
    assert [r.loss for r in res] == [r.loss for r in plain]
    st = eng.cache_stats
    assert st["evictions"] > 0
    assert st["insertions"] - st["evictions"] == st["clients_cached"]
    assert eng._device_cache.rows_used <= 12


def test_prep_failure_invalidates_cache():
    """A prep that dies between cache.plan and cache.apply leaves entries
    whose pool rows were never written; the engine must drop them so a
    retrying caller never gets served zero-filled 'hits'."""
    import pytest

    for depth in (0, 2):
        eng = _engine(depth, 64)
        eng.run(2)
        assert eng._device_cache.clients_cached > 0
        orig = eng.dataset.gather_batches

        def boom(cids, bidx, **kw):
            raise RuntimeError("gather died")

        eng.dataset.gather_batches = boom      # fails AFTER cache.plan ran
        with pytest.raises(RuntimeError, match="gather died"):
            eng.run(3)
        assert eng._device_cache.clients_cached == 0, depth
        assert eng._device_cache.rows_used == 0
        eng.dataset.gather_batches = orig
        res = eng.run(2)                       # retry trains on real bytes
        assert all(np.isfinite(r.loss) for r in res)


def test_engine_without_cache_reports_zeroes():
    res = _engine(1, 0).run(3)
    assert all(r.cache_hit_rate == 0.0 for r in res)
    assert all(r.cache_bytes_saved == 0 for r in res)
    assert _engine(1, 0).cache_stats == {}


# -- capacity in bytes --------------------------------------------------------

def test_capacity_bytes_converts_to_rows_and_tighter_limit_wins():
    import pytest

    cache = DeviceBatchCache(capacity_bytes=1000, row_bytes=96)
    assert cache.capacity == 1000 // 96
    # jointly: the tighter of rows/bytes wins
    assert DeviceBatchCache(4, capacity_bytes=1000, row_bytes=96).capacity == 4
    assert DeviceBatchCache(64, capacity_bytes=300, row_bytes=96).capacity == 3
    # a sub-row byte budget still yields one usable row
    assert DeviceBatchCache(capacity_bytes=10, row_bytes=96).capacity == 1
    with pytest.raises(ValueError, match="positive capacity"):
        DeviceBatchCache(0)
    with pytest.raises(ValueError, match="row_bytes"):
        DeviceBatchCache(capacity_bytes=1000)
    assert DeviceBatchCache(capacity_bytes=1000, row_bytes=96).stats()[
        "capacity_bytes"] == 1000


def test_probe_row_bytes_matches_packed_leaves():
    from repro.core.engine import _probe_row_bytes

    ds = _ds()
    got = _probe_row_bytes(ds, batch_size=2)
    batch = ds.gather_batches(np.asarray([0]), np.asarray([0]), batch_size=2)
    want = sum(int(np.prod(v.shape[1:])) * v.dtype.itemsize
               for v in batch.values())
    assert got == want > 0


def test_engine_byte_capacity_equivalent_to_row_capacity():
    """An engine given the byte budget of exactly R rows must behave
    identically to one given R rows: same losses, same hit accounting."""
    from repro.core.engine import _probe_row_bytes

    row_bytes = _probe_row_bytes(
        make_federated_dataset("sr", n_clients=64, input_dim=16,
                               batch_size=4, size_mu=2.5, size_sigma=0.8),
        batch_size=4)
    by_rows = _engine(1, 64)
    by_bytes = _engine(1, 0, cache_bytes=64 * row_bytes)
    assert by_bytes._device_cache.capacity == 64
    r1 = by_rows.run(8)
    r2 = by_bytes.run(8)
    assert [r.loss for r in r1] == [r.loss for r in r2]
    assert [r.cache_hit_rate for r in r1] == [r.cache_hit_rate for r in r2]
    s1, s2 = by_rows.cache_stats, by_bytes.cache_stats
    for k in ("hit_steps", "miss_steps", "insertions", "evictions"):
        assert s1[k] == s2[k], k


# -- per-shard pools (mesh execution) -----------------------------------------

def _shard_round(ds, cids, cache, t, shard, *, slot=0, steps_cap=3):
    """One single-worker round planned against one shard's pool."""
    assignment, workers = _assignment(ds, cids, workers=1)
    plan = plan_round(assignment, workers, steps_cap=steps_cap)
    S = s_bucket(plan.s_real)
    cplan = cache.plan(plan, S, t, shard=shard, worker_slot=slot)
    rows = gather_content_rows(ds, plan, cplan.content_mask,
                               cplan.n_miss_rows, batch_size=2)
    ref = build_round_arrays(ds, plan=plan, batch_size=2, s_align=s_bucket)
    miss = {k: jax.device_put(v) for k, v in rows.items()}
    out = cache.apply(miss, cplan)
    return out, cplan, ref


def test_per_shard_accounting_sums_to_global():
    """Hit/miss/bytes bookkeeping is kept per shard and the shard rows sum
    exactly to the global counters; hits land in the serving shard only."""
    ds = _ds()
    cache = DeviceBatchCache(64, n_shards=2)
    assert cache.capacity_per_shard == 32
    _shard_round(ds, [1, 2], cache, 0, shard=0)
    _shard_round(ds, [3, 4], cache, 0, shard=1)
    out, cp, ref = _shard_round(ds, [1, 2], cache, 1, shard=0)  # full hit
    assert cp.hit_clients == 2 and cp.shard == 0
    _assert_matches_ref(out, ref)
    st = cache.stats()
    assert st["n_shards"] == 2
    for key in ("hit_steps", "miss_steps", "hit_clients", "miss_clients",
                "insertions", "evictions", "bytes_saved", "rounds",
                "clients_cached", "rows_used"):
        assert sum(s[key] for s in st["per_shard"]) == st[key], key
    assert st["per_shard"][0]["hit_clients"] == 2
    assert st["per_shard"][1]["hit_clients"] == 0
    assert cache.shard_for_client(1) == 0
    assert cache.shard_for_client(3) == 1
    assert cache.shard_for_client(99) is None


def test_eviction_in_one_shard_never_touches_another():
    """Pressure on shard 0 evicts only shard-0 entries: shard 1's clients
    stay resident and keep hitting."""
    ds = _ds()
    nb = {c: min(ds.n_batches(c), 3) for c in range(16)}
    cap0 = nb[1] + nb[2]
    cache = DeviceBatchCache(2 * cap0, n_shards=2)
    _shard_round(ds, [1, 2], cache, 0, shard=0)     # fills shard 0 exactly
    _shard_round(ds, [5, 6], cache, 0, shard=1)
    resident_1 = set(cache._shards[1].entries)
    # new clients into shard 0 force evictions THERE...
    _, cp, _ = _shard_round(ds, [7, 8], cache, 1, shard=0)
    assert cp.evicted_clients > 0 and cp.shard == 0
    assert cache.stats()["per_shard"][0]["evictions"] > 0
    # ...while shard 1 is untouched and still hits
    assert set(cache._shards[1].entries) == resident_1
    assert cache.stats()["per_shard"][1]["evictions"] == 0
    _, cp1, _ = _shard_round(ds, [5, 6], cache, 2, shard=1)
    assert cp1.hit_clients == 2


def test_worker_slot_keys_isolate_round_bases():
    """Two workers of one shard in the same round must not share (and
    donate) one round base: distinct worker_slot keys get distinct bases."""
    ds = _ds()
    cache = DeviceBatchCache(64, n_shards=1)
    out_a, _, ref_a = _shard_round(ds, [1, 2], cache, 0, shard=0, slot=0)
    out_b, _, ref_b = _shard_round(ds, [3, 4], cache, 0, shard=0, slot=1)
    # both bases remain readable after the round (no cross-donation)
    _assert_matches_ref(out_a, ref_a)
    _assert_matches_ref(out_b, ref_b)
    assert len(cache._shards[0].bases) == 2


def test_capacity_must_cover_every_shard():
    import pytest

    with pytest.raises(ValueError, match="split over"):
        DeviceBatchCache(3, n_shards=4)
    with pytest.raises(ValueError, match="n_shards"):
        DeviceBatchCache(8, n_shards=0)


def test_retire_slots_drops_departed_workers_bases():
    """Churn shrinks a shard's worker set: the departed slot's full-size
    round base is dropped (it would otherwise stay resident forever), the
    surviving slot's base is untouched."""
    ds = _ds()
    cache = DeviceBatchCache(64, n_shards=1)
    out_a, _, ref_a = _shard_round(ds, [1, 2], cache, 0, shard=0, slot=0)
    _shard_round(ds, [3, 4], cache, 0, shard=0, slot=1)
    assert len(cache._shards[0].bases) == 2
    cache.retire_slots(0, 1)                 # slot 1's worker left
    assert len(cache._shards[0].bases) == 1
    assert all(k[2] == 0 for k in cache._shards[0].bases)
    assert cache._shards[0].max_slot == 0
    _assert_matches_ref(out_a, ref_a)        # survivor's base untouched
    # entries (pool rows) survive — only the per-slot bases are retired
    assert cache.clients_cached == 4
    cache.retire_slots(0, 0)                 # shard orphaned entirely
    assert len(cache._shards[0].bases) == 0
