"""HLO cost-walker validation: the trip-count-aware analysis must agree with
XLA's own cost_analysis on unrolled modules and correctly scale rolled scans
(XLA counts while bodies once — the bug this walker exists to fix)."""

import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import analyze_hlo, xla_cost_dict


def _scan_fn(unroll):
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        c, _ = jax.lax.scan(body, x, w, unroll=unroll)
        return c
    return f


def test_walker_matches_xla_on_unrolled():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    c = jax.jit(_scan_fn(True)).lower(x, w).compile()
    xla = float(xla_cost_dict(c)["flops"])
    mine = analyze_hlo(c.as_text()).flops
    assert abs(mine - xla) / xla < 0.02


def test_walker_scales_scan_by_trip_count():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    rolled = jax.jit(_scan_fn(False)).lower(x, w).compile()
    unrolled = jax.jit(_scan_fn(True)).lower(x, w).compile()
    f_rolled = analyze_hlo(rolled.as_text()).flops
    f_unrolled = analyze_hlo(unrolled.as_text()).flops
    assert abs(f_rolled - f_unrolled) / f_unrolled < 0.02
    # XLA's own count misses the 10x
    assert float(xla_cost_dict(rolled)["flops"]) < 0.2 * f_rolled


def test_nested_scan_multiplicity():
    def f(x, w):
        def outer(c, wi):
            def inner(ci, _):
                return jnp.tanh(ci @ wi), None
            ci, _ = jax.lax.scan(inner, c, None, length=5)
            return ci, None
        c, _ = jax.lax.scan(outer, x, w)
        return c
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    mine = analyze_hlo(c.as_text()).flops
    want = 4 * 5 * 2 * 64 ** 3                # 20 matmuls
    assert abs(mine - want) / want < 0.1


def test_grad_through_scan_counted():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        c, _ = jax.lax.scan(body, x, w)
        return jnp.sum(c)
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
    c = jax.jit(jax.grad(f, argnums=(0, 1))).lower(x, w).compile()
    mine = analyze_hlo(c.as_text()).flops
    # fwd (6) + 2 dots per step in bwd (12) = >= 18 matmuls
    assert mine > 17 * 2 * 64 ** 3


def test_collectives_with_multiplicity():
    """Sharded scan emits loop collectives; the walker must scale them by
    the trip count.  Runs in a subprocess so the 4 placeholder devices do
    not leak into the 1-device test session."""
    import subprocess
    import sys
    import os
    script = r"""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_cost import analyze_hlo, xla_cost_dict
from repro.launch.mesh import mesh_axis_types_kwargs
mesh = jax.make_mesh((2, 2), ("a", "b"), **mesh_axis_types_kwargs("ab"))
def f(x, w):
    def body(c, wi):
        return c @ wi, None
    c, _ = jax.lax.scan(body, x, w)
    return c
x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
w = jax.ShapeDtypeStruct((7, 256, 256), jnp.float32)
jf = jax.jit(f, in_shardings=(NamedSharding(mesh, P("a", "b")),
                              NamedSharding(mesh, P(None, "b", None))))
c = jf.lower(x, w).compile()
cost = analyze_hlo(c.as_text())
assert cost.collectives, "expected TP all-reduces in the loop"
assert [cc for cc in cost.collectives if cc.multiplicity >= 7], \
    "loop collectives must carry the trip multiplicity"
ici, dcn = cost.wire_bytes(pod_size=0)
assert ici > 0 and dcn == 0
print("OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
