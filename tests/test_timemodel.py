"""Eq. 3 / Eq. 4 time-model tests (unit + property)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.timemodel import (TrainingTimeModel, fit_linear,
                                  fit_log_linear)


def _synth(a, b, c, d, n=200, noise=0.0, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.integers(1, 500, size=n).astype(np.float64)
    t = a * x + b * np.log(c * x) + d
    if noise:
        t = t * rng.lognormal(0.0, noise, size=n)
    return x, np.maximum(t, 1e-3)


def test_fit_recovers_noiseless_curve():
    x, t = _synth(0.05, 0.8, 0.5, 1.2)
    fit = fit_log_linear(x, t)
    pred = fit.predict(x)
    assert np.allclose(pred, t, rtol=1e-4, atol=1e-4)
    assert fit.sse < 1e-4


def test_loglinear_beats_linear_on_log_data():
    """Paper Fig. 7: the log-linear family fits the skewed empirical curve
    with lower SSE than a plain line."""
    x, t = _synth(0.01, 3.0, 1.0, 0.5, noise=0.02)
    ll = fit_log_linear(x, t)
    lin = fit_linear(x, t)
    assert ll.sse < lin.sse


def test_loglinear_matches_linear_data():
    """§4.2.1: 'the log-linear curve can always fit linear behavior'."""
    rng = np.random.default_rng(1)
    x = rng.integers(1, 300, 150).astype(float)
    t = 0.2 * x + 3.0
    ll = fit_log_linear(x, t)
    assert np.allclose(ll.predict(x), t, rtol=1e-3, atol=1e-2)


@settings(max_examples=25, deadline=None)
@given(a=st.floats(0.001, 0.5), b=st.floats(0.05, 3.0),
       d=st.floats(0.0, 5.0), noise=st.floats(0.0, 0.3),
       seed=st.integers(0, 1000))
def test_predictions_never_negative(a, b, d, noise, seed):
    """§4.2.1: the fitted function never predicts negative time."""
    x, t = _synth(a, b, 1.0, d, noise=noise, seed=seed)
    fit = fit_log_linear(x, t)
    grid = np.arange(1, 2000, dtype=np.float64)
    assert np.all(fit.predict(grid) > 0)


def test_degenerate_inputs():
    fit = fit_log_linear([5.0], [2.0])
    assert fit.predict(10.0) > 0
    lin = fit_linear([], [])
    assert lin.predict(3.0) > 0
    with pytest.raises(ValueError):
        fit_log_linear([0.0, 1.0, 2.0], [1.0, 1.0, 1.0])


def test_round_protocol_uses_t_minus_2():
    """§4.2: the fit for round t only uses telemetry from rounds <= t-2."""
    m = TrainingTimeModel()
    # poison rounds >= 1 with garbage; clean data in round 0
    x, t = _synth(0.05, 0.8, 0.5, 1.2, n=100)
    m.observe(0, x, t)
    m.observe(1, x, t * 100.0)
    m.refit(2)          # may use rounds <= 0 only
    assert m.ready
    pred = m.predict(50.0)
    truth = 0.05 * 50 + 0.8 * np.log(0.5 * 50) + 1.2
    assert pred < truth * 10  # the x100 round must not have been used


def test_not_ready_before_data():
    m = TrainingTimeModel()
    assert not m.ready
    with pytest.raises(RuntimeError):
        m.predict(10)
    m.observe(0, [1, 2, 3], [1.0, 1.1, 1.2])
    m.refit(1)          # cutoff = -1: nothing usable yet
    assert not m.ready


def test_adaptive_correction_blends_recent():
    """Eq. 4: g(x) = 1/2 (f(x) + recent mean at x)."""
    m = TrainingTimeModel()
    x, t = _synth(0.05, 0.8, 0.5, 1.2, n=300)
    m.observe(0, x, t)
    m.observe(1, x, t)
    # round 3 sees a 2x system slowdown in the recent window (round 1)
    m2 = TrainingTimeModel()
    m2.observe(0, x, t)
    m2.observe(1, x, t * 2.0)
    m.refit(3)
    m2.refit(3)
    p1 = m.predict(100.0)
    p2 = m2.predict(100.0)
    # the correction must move the prediction toward the slowdown, halfway
    assert p2 > p1 * 1.3
    assert p2 < p1 * 2.0


def test_max_points_retention():
    m = TrainingTimeModel(max_points=50)
    for r in range(10):
        m.observe(r, np.arange(1, 21), np.arange(1, 21, dtype=float))
    assert m.n_points == 50
