"""Manual EP dispatch (shard_map) — correctness vs the auto-sharding
reference, run in a subprocess with 8 placeholder devices so the 1-device
test session is untouched."""

import os
import subprocess
import sys

SCRIPT = r"""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed.ep_dispatch import make_ep_dispatch
from repro.models.layers import moe_layer_3d

from repro.launch.mesh import mesh_axis_types_kwargs
mesh = jax.make_mesh((2, 4), ('data', 'model'),
                     **mesh_axis_types_kwargs(('data', 'model')))
b, s, D, E, F, k = 4, 16, 32, 8, 16, 2
ks = jax.random.split(jax.random.key(0), 5)
x = jax.random.normal(ks[0], (b, s, D))
rw = jax.random.normal(ks[1], (D, E)) * 0.1
gw = jax.random.normal(ks[2], (E, D, F)) * 0.1
uw = jax.random.normal(ks[3], (E, D, F)) * 0.1
dw = jax.random.normal(ks[4], (E, F, D)) * 0.1
disp = make_ep_dispatch(mesh, batch_axes=('data',), fsdp_axis='data')
cf = E / k   # droppless: local-capacity routing == global routing

def f(x, rw, gw, uw, dw):
    return disp(x, rw, gw, uw, dw, top_k=k, capacity_factor=cf)

jf = jax.jit(f, in_shardings=(
    NamedSharding(mesh, P('data', None, None)),
    NamedSharding(mesh, P(None, None)),
    NamedSharding(mesh, P('model', 'data', None)),
    NamedSharding(mesh, P('model', 'data', None)),
    NamedSharding(mesh, P('model', None, 'data'))))
out, aux = jf(x, rw, gw, uw, dw)
ref, _ = moe_layer_3d(x, rw, gw, uw, dw, top_k=k, capacity_factor=cf,
                      impl='scatter')
err = float(jnp.abs(out - ref).max())
assert err < 1e-5, err

# gradients flow through the shard_map
g = jax.grad(lambda gw: jf(x, rw, gw, uw, dw)[0].astype(jnp.float32).sum())(gw)
assert float(jnp.abs(g).sum()) > 0

# the compiled module must contain no all-to-all / token all-gather: the
# only collectives are the combine psum (+ FSDP weight gathers)
txt = jf.lower(jax.ShapeDtypeStruct(x.shape, x.dtype),
               jax.ShapeDtypeStruct(rw.shape, rw.dtype),
               jax.ShapeDtypeStruct(gw.shape, gw.dtype),
               jax.ShapeDtypeStruct(uw.shape, uw.dtype),
               jax.ShapeDtypeStruct(dw.shape, dw.dtype)).compile().as_text()
assert 'all-to-all(' not in txt
print('OK')
"""


def test_ep_dispatch_matches_reference_and_grads():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
