"""Compressed cross-shard combine: engine-level invariants.

The contract mirrors the mesh decomposition invariant: ``none`` is the
bit-exact reference (pinned by tests/test_mesh.py's acceptance matrix,
which sets it explicitly); ``int8``/``topk`` are themselves deterministic
and depth-invariant (residuals are consumer state in strict round order),
shrink ``combine_bytes`` by the gated ratios, keep the loss METRIC exact
(weight/loss scalars never compress), and converge within tolerance of the
exact run.  Checkpointed residuals make a resumed compressed run bit-match
the uninterrupted one.
"""

import jax
import pytest

from repro.checkpoint import CheckpointStore
from repro.core import (EngineConfig, FederatedEngine, SyntheticTelemetry,
                        UniformSampler, make_placement)
from repro.data import make_federated_dataset
from repro.distributed import WorkerPool
from repro.models.papertasks import make_task_model
from repro.optim import sgd


def _engine(compress="none", mode="tree", frac=0.05, depth=1, mesh=2,
            ckpt=None, ckpt_every=2, **cfg):
    ds = make_federated_dataset("sr", n_clients=64, input_dim=16,
                                batch_size=4, size_mu=2.5, size_sigma=0.8)
    params, loss = make_task_model("sr", jax.random.key(0), input_dim=16,
                                   width=32, n_blocks=2)
    return FederatedEngine(
        dataset=ds, loss_fn=loss, init_params=params,
        optimizer=sgd(0.1, momentum=0.9),
        placement=make_placement("lb"), sampler=UniformSampler(64, 8),
        pool=WorkerPool.homogeneous(4, type_name="a40", concurrency=2),
        telemetry=SyntheticTelemetry(),
        checkpoint_store=(CheckpointStore(ckpt, keep=3)
                          if ckpt is not None else None),
        config=EngineConfig(steps_cap=4, batch_size=4, lanes_per_worker=2,
                            pipeline_depth=depth, mesh_workers=mesh,
                            combine_mode=mode, combine_compress=compress,
                            combine_topk_frac=frac,
                            rounds_per_checkpoint=ckpt_every, **cfg))


# -- config validation --------------------------------------------------------

def test_compress_requires_tree_mode():
    with pytest.raises(ValueError, match="combine_mode"):
        EngineConfig(mesh_workers=2, combine_mode="flat",
                     combine_compress="int8")


def test_compress_mode_validated():
    with pytest.raises(ValueError, match="combine_compress"):
        EngineConfig(mesh_workers=2, combine_mode="tree",
                     combine_compress="fp4")


@pytest.mark.parametrize("frac", [0.0, -0.5, 1.01])
def test_topk_frac_validated(frac):
    with pytest.raises(ValueError, match="combine_topk_frac"):
        EngineConfig(mesh_workers=2, combine_mode="tree",
                     combine_compress="topk", combine_topk_frac=frac)


# -- determinism and depth invariance -----------------------------------------

@pytest.mark.parametrize("compress", ["int8", "topk"])
def test_compressed_losses_depth_invariant(compress):
    """Residuals are consumer-side state mutated in strict round order, so
    pipeline depth cannot reorder them: compressed losses are bit-identical
    across depths 0/1/2 (same invariant the exact path guarantees)."""
    base = _engine(compress, depth=0).run(4)
    for depth in (1, 2):
        res = _engine(compress, depth=depth).run(4)
        assert [r.loss for r in res] == [r.loss for r in base], \
            f"compress={compress} depth={depth}"


def test_compressed_run_deterministic():
    a = _engine("int8").run(3)
    b = _engine("int8").run(3)
    assert [r.loss for r in a] == [r.loss for r in b]


def test_first_round_loss_metric_exact():
    """Loss scalars never compress and round 0 trains on identical params,
    so the round-0 loss METRIC matches the exact tree path bitwise — only
    params (and hence later rounds) feel quantization."""
    exact = _engine("none").run(1)
    for compress in ("int8", "topk"):
        got = _engine(compress).run(1)
        assert got[0].loss == exact[0].loss, compress


# -- the perf contract --------------------------------------------------------

def test_combine_bytes_shrink_ratios():
    """The gated wire-format ratios, measured on the engine's own byte
    accounting: int8 >= 3.5x and topk(0.05) >= 10x vs the FLAT combine
    (which ships every worker lane's dense partial)."""
    flat = _engine("none", mode="flat").run(2)[-1].combine_bytes
    tree = _engine("none", mode="tree").run(2)[-1].combine_bytes
    int8 = _engine("int8").run(2)[-1].combine_bytes
    topk = _engine("topk", frac=0.05).run(2)[-1].combine_bytes
    assert flat > tree > int8 > topk > 0
    assert flat / int8 >= 3.5
    assert flat / topk >= 10.0


@pytest.mark.parametrize("compress", ["int8", "topk"])
def test_compressed_loss_tracks_exact(compress):
    """Error feedback keeps compressed training near the exact trajectory:
    final loss at most 25% WORSE than the exact tree run over 6 rounds
    (documented degradation tolerance — signed, because error feedback's
    smoothing often converges lower; int8 is far tighter in practice)."""
    exact = _engine("none").run(6)[-1].loss
    got = _engine(compress).run(6)[-1].loss
    assert (got - exact) / abs(exact) < 0.25, f"{got} vs {exact}"


def test_residual_norm_reported():
    res = _engine("int8").run(3)
    assert all(r.residual_norm > 0 for r in res)
    exact = _engine("none").run(3)
    assert all(r.residual_norm == 0.0 for r in exact)


def test_controller_journals_compressed_combine():
    e = _engine("int8", drift_threshold=0.4)   # a live control plane
    res = e.run(3)
    assert len(e.control.compress_log) == 3
    t, nbytes, norm = e.control.compress_log[-1]
    assert t == 2 and nbytes == res[-1].combine_bytes and norm > 0
    assert e.control.stats()["combine_compress"]["rounds"] == 3


# -- checkpoint/resume --------------------------------------------------------

@pytest.mark.parametrize("compress", ["int8", "topk"])
def test_resumed_compressed_run_matches_uninterrupted(compress, tmp_path):
    """The error-feedback residual tree rides the checkpoint aux sidecar:
    restore + run == uninterrupted run, bitwise — the invariant that fails
    (error re-lost once) if residuals were silently zeroed on restore."""
    base = _engine(compress).run(6)
    _engine(compress, ckpt=str(tmp_path)).run(4)   # checkpoints at 2 and 4
    e = _engine(compress, ckpt=str(tmp_path))
    assert e.restore_latest()
    assert e.round_idx == 4
    res = e.run(2)
    assert [r.loss for r in res] == [r.loss for r in base[4:]]


def test_restore_with_mismatched_compressor_warns_not_crashes(tmp_path,
                                                              capsys):
    _engine("topk", frac=0.05, ckpt=str(tmp_path)).run(2)
    e = _engine("topk", frac=0.10, ckpt=str(tmp_path))
    assert e.restore_latest()
    assert "combine_compress state" in capsys.readouterr().out
    e.run(1)  # still functional, just warm-started without residuals
