"""Open-world population: the hash-derived registry stays O(1) in memory,
the nested-threshold arrival model is monotone and matches its analytic
expectation, the streaming sampler fills cohorts with bounded draws (stale
fill terminates for every pool state), every sampler checkpoint
round-trips, and open-world engine runs are bit-identical across pipeline
depths with the controller live."""

import json
import tracemalloc

import jax
import numpy as np
import pytest

from repro.core import (EngineConfig, FederatedEngine, SyntheticTelemetry,
                        UniformSampler, ZipfSampler, make_placement)
from repro.core.sampling import (PowerOfChoiceSampler, restore_sampler,
                                 sampler_state)
from repro.data import make_federated_dataset
from repro.distributed import WorkerPool
from repro.models.papertasks import make_task_model
from repro.optim import sgd
from repro.population import (ArrivalIndex, ClientMetadataStore, Intervention,
                              OnlinePoolSampler, PopulationDataset)


# -- client-metadata store ----------------------------------------------------

def test_store_attributes_deterministic_and_vectorized():
    store = ClientMetadataStore(10_000, seed=3, batch_size=4)
    cids = np.arange(0, 10_000, 97)
    # vectorized call == scalar calls, and repeat calls are identical
    np.testing.assert_array_equal(store.phase(cids), store.phase(cids))
    assert store.phase(int(cids[5])) == store.phase(cids)[5]
    assert store.region(int(cids[7])) == store.region_names[
        int(store.region_idx(cids)[7])]
    sizes = store.n_samples(cids)
    assert sizes[3] == store.n_samples(int(cids[3]))
    assert isinstance(store.n_samples(int(cids[0])), int)
    # phases are uniform-ish on [0, 1) (hash quality sanity)
    ph = store.phase(np.arange(10_000))
    assert 0.0 <= ph.min() and ph.max() < 1.0
    assert abs(ph.mean() - 0.5) < 0.02


def test_store_sizes_floored_to_one_batch_and_clipped():
    store = ClientMetadataStore(5_000, seed=7, batch_size=20,
                                size_max=1_000)
    sizes = store.n_samples(np.arange(5_000))
    assert sizes.min() >= 20          # paper §5.1: at least one full batch
    assert sizes.max() <= 1_000
    batches = store.n_batches(np.arange(5_000))
    assert batches.min() >= 1
    np.testing.assert_array_equal(batches, np.maximum(1, sizes // 20))


def test_store_memory_independent_of_population():
    """Registering 1M clients must cost the same few KB as 10k — the
    registry is hash streams, never a materialized table."""
    def peak_kb(population):
        tracemalloc.start()
        store = ClientMetadataStore(population, seed=1)
        _ = store.n_samples(np.arange(64))       # touch every stream
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        return peak / 1024

    small, big = peak_kb(10_000), peak_kb(1_000_000)
    assert big < 64, f"1M-client store peaked at {big:.1f}KB"
    # comparative, with generous slack for allocator noise (both are ~KB)
    assert big <= small * 8 + 8, (small, big)


def test_store_state_round_trips_attributes():
    store = ClientMetadataStore(4_096, seed=5, batch_size=8, size_mu=3.0)
    clone = ClientMetadataStore.from_state(
        json.loads(json.dumps(store.state_dict())))
    cids = np.arange(0, 4_096, 13)
    np.testing.assert_array_equal(store.phase(cids), clone.phase(cids))
    np.testing.assert_array_equal(store.n_samples(cids),
                                  clone.n_samples(cids))
    np.testing.assert_array_equal(store.region_idx(cids),
                                  clone.region_idx(cids))


def test_population_dataset_grafts_sizes_not_content():
    base = make_federated_dataset("sr", n_clients=64, input_dim=8,
                                  batch_size=2)
    store = ClientMetadataStore(1_000_000, seed=2, batch_size=2)
    ds = PopulationDataset(base, store)
    assert ds.n_clients == 1_000_000
    assert ds.n_samples(999_999) == int(store.n_samples(999_999))
    # content delegates to the lazy base — identical bytes for same cid
    a = ds.client_batch(123_456, 0)
    b = base.client_batch(123_456, 0)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    with pytest.raises(ValueError, match="batch_size"):
        PopulationDataset(base, ClientMetadataStore(100, batch_size=4))


# -- arrival index ------------------------------------------------------------

def test_nested_threshold_is_monotone_in_rate():
    """Raising the online rate only ever ADDS clients (stable diurnal
    membership: the same devices come back every evening)."""
    store = ClientMetadataStore(20_000, seed=9)
    base = ArrivalIndex(store)
    surged = ArrivalIndex(store, interventions=(
        Intervention("surge", 0, 1_000, 1.5),))
    cids = np.arange(20_000)
    for t in (0, 7, 19, 33):
        lo, hi = base.online(cids, t), surged.online(cids, t)
        assert not np.any(lo & ~hi), "surge dropped an online client"
        assert hi.sum() >= lo.sum()


def test_expected_online_matches_empirical_fraction():
    store = ClientMetadataStore(50_000, seed=4)
    index = ArrivalIndex(store)
    cids = np.arange(50_000)
    for t in (0, 11, 24, 40):
        frac = index.online(cids, t).mean()
        expect = index.expected_online(t) / store.population
        assert abs(frac - expect) < 0.02, (t, frac, expect)


def test_outage_intervention_is_region_scoped_and_windowed():
    store = ClientMetadataStore(30_000, seed=6)
    index = ArrivalIndex(store, interventions=(
        Intervention("outage", 10, 20, 0.0, region="apac"),))
    cids = np.arange(30_000)
    apac = index.store.region_idx(cids) == list(
        index.store.region_names).index("apac")
    during, outside = index.online(cids, 15), index.online(cids, 25)
    assert not np.any(during & apac), "apac client online mid-outage"
    assert np.any(during & ~apac), "outage leaked outside its region"
    assert np.any(outside & apac), "apac never came back"
    assert index.online_fraction("apac", 15) == 0.0
    assert index.online_fraction("apac", 20) > 0.0   # [start, end)


# -- streaming sampler --------------------------------------------------------

def test_sampler_fills_unique_cohort_with_bounded_draws():
    store = ClientMetadataStore(100_000, seed=13)
    index = ArrivalIndex(store)
    s = OnlinePoolSampler(index, 64, seed=13)
    cohort = s.sample(0)
    assert len(cohort) == 64 == len(set(cohort.tolist()))
    assert s.last_stats["draws"] <= s.max_draw_factor * 64
    assert s.last_stats["stale_fraction"] == 0.0
    assert s.last_stats["online_pool"] == index.expected_online(0)
    # probes are O(cohort), not O(population)
    assert index.probes <= s.max_draw_factor * 64


def test_sampler_blackout_stale_fills_deterministically():
    """All clients offline: the cohort still fills (unique, stale 1.0),
    terminates, and two identically-seeded samplers agree bit-for-bit."""
    def draw():
        store = ClientMetadataStore(1_000, seed=21)
        index = ArrivalIndex(store, interventions=(
            Intervention("outage", 0, 10**6, 0.0),))
        s = OnlinePoolSampler(index, 32, seed=21)
        return s.sample(5), s.last_stats

    (a, stats), (b, _) = draw(), draw()
    assert stats["stale_fraction"] == 1.0 and stats["online"] == 0
    assert len(set(a.tolist())) == 32
    np.testing.assert_array_equal(a, b)


def test_sampler_cohort_larger_than_population_wraps():
    store = ClientMetadataStore(8, seed=2)
    index = ArrivalIndex(store)
    s = OnlinePoolSampler(index, 16, seed=2)
    cohort = s.sample(0)
    assert len(cohort) == 16
    assert cohort.min() >= 0 and cohort.max() < 8


# -- checkpoint round-trips, all samplers -------------------------------------

def test_every_sampler_kind_checkpoint_round_trips():
    """uniform / zipf / poc / online: JSON-serialized sampler_state restores
    a sampler whose subsequent draws are bit-identical."""
    def online():
        store = ClientMetadataStore(10_000, seed=17)
        return OnlinePoolSampler(
            ArrivalIndex(store, interventions=(
                Intervention("surge", 2, 9, 1.3, region="emea"),)),
            16, seed=17)

    makers = (lambda: UniformSampler(500, 8, seed=5),
              lambda: ZipfSampler(500, 8, a=1.4, seed=5),
              lambda: PowerOfChoiceSampler(500, 8, seed=5),
              online)
    for make in makers:
        s = make()
        s.sample(0)
        state = json.loads(json.dumps(sampler_state(s)))
        expect = [s.sample(t) for t in range(1, 4)]
        r = restore_sampler(state)
        for t, want in zip(range(1, 4), expect):
            np.testing.assert_array_equal(r.sample(t), want)
    # the online state embeds the full arrival config
    st = sampler_state(online())
    assert st["kind"] == "online" and "index" in st
    assert st["index"]["interventions"][0]["region"] == "emea"


def test_power_of_choice_signature_matches_other_samplers():
    """Regression: ``sample(round_idx)`` must work with NO oracle (uniform
    degenerate pick), the ctor oracle must equal the per-call oracle, and
    the oracle must still select the top-loss clients."""
    uniform = PowerOfChoiceSampler(200, 8, seed=3).sample(0)
    assert len(uniform) == 8
    oracle = lambda cid: float(cid)          # noqa: E731 — loss == id
    by_ctor = PowerOfChoiceSampler(200, 8, seed=3,
                                   client_loss=oracle).sample(0)
    by_call = PowerOfChoiceSampler(200, 8, seed=3).sample(0, oracle)
    np.testing.assert_array_equal(by_ctor, by_call)
    # top-loss selection: the chosen 8 are the largest ids of the d drawn
    cand = PowerOfChoiceSampler(200, 8, seed=3).rng.choice(
        200, size=16, replace=False)
    assert sorted(by_ctor.tolist()) == sorted(cand.tolist())[-8:]


# -- engine integration -------------------------------------------------------

def _engine(depth, *, population=4_096, cohort=16, seed=11,
            drift_threshold=0.0, ckpt=None, placement="lb",
            rounds_per_checkpoint=25):
    base = make_federated_dataset("sr", n_clients=256, input_dim=16,
                                  batch_size=4)
    store = ClientMetadataStore(population, seed=seed, batch_size=4)
    sampler = OnlinePoolSampler(ArrivalIndex(store), cohort, seed=seed)
    params, loss = make_task_model("sr", jax.random.key(0), input_dim=16,
                                   width=32, n_blocks=1)
    eng = FederatedEngine(
        dataset=PopulationDataset(base, store), loss_fn=loss,
        init_params=params, optimizer=sgd(0.1, momentum=0.9),
        placement=make_placement(placement), sampler=sampler,
        pool=WorkerPool.homogeneous(3, type_name="a40", concurrency=2),
        telemetry=SyntheticTelemetry(seed=seed),
        config=EngineConfig(steps_cap=4, batch_size=4, pipeline_depth=depth,
                            drift_threshold=drift_threshold,
                            rounds_per_checkpoint=rounds_per_checkpoint),
        checkpoint_store=ckpt)
    return eng


def test_open_world_losses_bit_identical_across_depths_with_controller():
    results = {}
    for depth in (0, 1, 2):
        res = _engine(depth, population=100_000,
                      drift_threshold=0.6).run(4)
        results[depth] = res
    losses = {d: [r.loss for r in rs] for d, rs in results.items()}
    assert losses[0] == losses[1] == losses[2], losses
    # SLO metrics populated identically at every depth
    for r0, r1, r2 in zip(*results.values()):
        assert r0.slo_p99 >= r0.slo_p50 > 0.0
        assert 0.0 <= r0.stale_fraction <= 1.0
        assert r0.online_pool > 0.0
        assert (r0.slo_p50, r0.slo_p99, r0.stale_fraction, r0.online_pool) \
            == (r1.slo_p50, r1.slo_p99, r1.stale_fraction, r1.online_pool) \
            == (r2.slo_p50, r2.slo_p99, r2.stale_fraction, r2.online_pool)


def test_million_client_round_is_o_cohort():
    """A 64-client round over a 1M-client registry: the population stack
    costs the same memory as a 10k one, and the sampler probes O(cohort)
    ids per round."""
    base = make_federated_dataset("sr", n_clients=256, input_dim=16,
                                  batch_size=4)

    def stack_peak_kb(population):
        tracemalloc.start()
        store = ClientMetadataStore(population, seed=11, batch_size=4)
        sampler = OnlinePoolSampler(ArrivalIndex(store), 64, seed=11)
        ds = PopulationDataset(base, store)
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        return peak / 1024, sampler, ds

    small, _, _ = stack_peak_kb(10_000)
    big, sampler, ds = stack_peak_kb(1_000_000)
    assert big < 64 and big <= small * 8 + 8, (small, big)

    params, loss = make_task_model("sr", jax.random.key(0), input_dim=16,
                                   width=32, n_blocks=1)
    eng = FederatedEngine(
        dataset=ds, loss_fn=loss, init_params=params,
        optimizer=sgd(0.1, momentum=0.9), placement=make_placement("lb"),
        sampler=sampler,
        pool=WorkerPool.homogeneous(3, type_name="a40", concurrency=2),
        telemetry=SyntheticTelemetry(seed=11),
        config=EngineConfig(steps_cap=4, batch_size=4, pipeline_depth=0))
    res = eng.run(2)
    assert all(r.n_clients == 64 for r in res)
    assert sampler.index.probes <= 2 * sampler.max_draw_factor * 64


def test_checkpoint_resume_replays_online_stream(tmp_path):
    """A resumed open-world run continues the exact sampler stream: the
    checkpointed state (store config, traces, RNG position) overrides the
    restoring process's sampler and round 4 is bit-identical."""
    from repro.checkpoint import CheckpointStore

    a = _engine(1, placement="rr", rounds_per_checkpoint=2,
                ckpt=CheckpointStore(str(tmp_path)))
    whole = a.run(5)                       # checkpoints at rounds 2 and 4
    b = _engine(1, placement="rr", rounds_per_checkpoint=2,
                ckpt=CheckpointStore(str(tmp_path)))
    b.sampler = OnlinePoolSampler(         # "wrong" sampler on the resume
        ArrivalIndex(ClientMetadataStore(4_096, seed=999, batch_size=4)),
        16, seed=999)
    assert b.restore_latest()
    assert b.round_idx == 4
    assert isinstance(b.sampler, OnlinePoolSampler)
    assert b.sampler.seed == 11            # checkpoint config wins
    res = b.run(1)
    assert res[0].loss == whole[4].loss
    assert res[0].n_clients == whole[4].n_clients
    assert res[0].online_pool == whole[4].online_pool


# -- scenario storms ----------------------------------------------------------

def test_surge_storm_swells_pool_without_false_drift():
    from repro.control.scenarios import run_scenario

    out = run_scenario("surge")
    assert out["pool_gain_x"] == pytest.approx(1.5, abs=0.1)
    assert out["false_drifts"] == 0 and out["fallback_rounds"] == 0
    assert out["audit_violations"] == 0
    assert out["stale_peak"] == 0.0
    # O(cohort) probes per round, not O(population)
    assert out["probes_per_round"] < 16 * 64


def test_outage_storm_drops_and_recovers_pool():
    from repro.control.scenarios import run_scenario

    out = run_scenario("outage")
    assert 0.2 < out["pool_drop_fraction"] < 0.5   # apac's ~1/3 share
    assert out["recovered"], out
    assert out["false_drifts"] == 0 and out["audit_violations"] == 0
    # deterministic: a second run reproduces the numbers exactly
    assert run_scenario("outage") == out
