"""Observability plane: span tracer, Perfetto export, round critique,
flight recorder — and the tentpole invariant that tracing NEVER perturbs
training (losses and SLO fields bit-identical with the tracer on or off,
across pipeline depths and mesh shard counts, controller live)."""

import json
import threading

import jax
import pytest

from repro.core import (EngineConfig, FederatedEngine, SyntheticTelemetry,
                        UniformSampler, make_placement)
from repro.data import make_federated_dataset
from repro.distributed import WorkerPool
from repro.models.papertasks import make_task_model
from repro.obs import (NULL_TRACER, FlightRecorder, MetricsRegistry, Tracer,
                       critique_round, make_observability, trace_events,
                       write_trace)
from repro.optim import sgd


def _engine(mesh=0, depth=1, obs=None, drift=0.0, adapt=0,
            granularity="type"):
    ds = make_federated_dataset("sr", n_clients=64, input_dim=16,
                                batch_size=4, size_mu=2.5, size_sigma=0.8)
    params, loss = make_task_model("sr", jax.random.key(0), input_dim=16,
                                   width=32, n_blocks=2)
    return FederatedEngine(
        dataset=ds, loss_fn=loss, init_params=params,
        optimizer=sgd(0.1, momentum=0.9),
        placement=make_placement("lb"), sampler=UniformSampler(64, 8),
        pool=WorkerPool.homogeneous(4, type_name="a40", concurrency=2),
        telemetry=SyntheticTelemetry(),
        config=EngineConfig(steps_cap=4, batch_size=4, lanes_per_worker=2,
                            pipeline_depth=depth, mesh_workers=mesh,
                            drift_threshold=drift, adapt_interval=adapt,
                            adapt_granularity=granularity),
        obs=obs)


def _signature(results):
    """Everything the tracer must not perturb: training losses, the
    simulated schedule, and the deadline-SLO fields."""
    return [(r.loss, r.makespan, r.idle_time, r.slo_p50, r.slo_p99,
             r.n_clients) for r in results]


# -- ring buffer + tracer (unit) ----------------------------------------------

def test_ring_wraparound_keeps_newest_and_counts_dropped():
    tr = Tracer(capacity=16)
    for i in range(40):
        tr.instant(f"ev{i}")
    st = tr.stats()
    assert st["spans"] == 16 and st["dropped"] == 24
    assert tr.dropped == 24
    names = [r[1] for r in tr.snapshot()]
    # overwrite-oldest: exactly the newest 16 events survive, in order
    assert names == [f"ev{i}" for i in range(24, 40)]


def test_tracer_capacity_floor_and_never_blocks():
    tr = Tracer(capacity=1)            # clamped up to the 16-slot floor
    assert tr.capacity == 16
    for i in range(100):
        tr.counter("c", float(i))
    assert tr.stats()["spans"] == 16   # degraded, never raised/blocked


def test_span_nesting_records_depth_per_thread():
    tr = Tracer()
    with tr.span("outer", t=1):
        with tr.span("inner"):
            pass
        tr.instant("mark")
    recs = {r[1]: r for r in tr.snapshot()}
    assert recs["inner"][5] == 1       # nested one level down
    assert recs["outer"][5] == 0
    assert recs["mark"][5] == 1        # emitted inside the outer span
    assert recs["outer"][4] == threading.current_thread().name
    assert recs["outer"][6] == {"t": 1}


def test_lanes_are_thread_names_and_add_span_overrides():
    tr = Tracer()

    def work():
        with tr.span("threaded"):
            pass

    th = threading.Thread(target=work, name="pollen-pack_0")
    th.start()
    th.join()
    tr.add_span("sync", 1.0, 0.5, lane="worker3", wid=3)
    lanes = {r[1]: r[4] for r in tr.snapshot()}
    assert lanes["threaded"] == "pollen-pack_0"
    assert lanes["sync"] == "worker3"


def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    with NULL_TRACER.span("x"):
        NULL_TRACER.instant("y")
        NULL_TRACER.counter("z", 1.0)
        NULL_TRACER.add_span("w", 0.0, 1.0)
    assert NULL_TRACER.snapshot() == []
    assert NULL_TRACER.stats()["spans"] == 0


# -- metrics registry (unit) --------------------------------------------------

def test_metrics_registry_counters_gauges_histograms():
    m = MetricsRegistry()
    m.inc("rounds")
    m.inc("rounds", 2)
    m.gauge("loss", 0.5)
    m.gauge("loss", 0.25)
    for v in (0.0005, 0.05, 5.0, 100.0):
        m.observe("wall_s", v)
    snap = m.snapshot()
    assert snap["counters"]["rounds"] == 3
    assert snap["gauges"]["loss"] == 0.25
    h = snap["histograms"]["wall_s"]
    assert h["n"] == 4 and h["sum"] == pytest.approx(105.0505)
    assert len(h["counts"]) == len(h["edges"]) + 1
    assert sum(h["counts"]) == 4
    assert h["counts"][0] == 1          # 0.0005 below the first edge
    assert h["counts"][-1] == 1         # 100.0 above the last edge


# -- round critique (unit) ----------------------------------------------------

def test_critique_idle_fraction_and_critical_path():
    c = critique_round(round_idx=3, pack_s=0.2, overlap_s=0.2, exec_s=1.0,
                       combine_s=0.1, makespan=2.0, idle_time=1.0,
                       n_workers=4)
    assert c.idle_fraction == pytest.approx(1.0 / 8.0)
    assert c.critical_path == "exec"    # 0.9 exec beats 0.1 combine
    d = c.as_dict()
    assert d["round"] == 3 and d["critical_path"] == "exec"
    # fully exposed pack dominating everything => pack-bound round
    c2 = critique_round(round_idx=0, pack_s=3.0, overlap_s=0.0, exec_s=1.0)
    assert c2.critical_path == "pack"


# -- Perfetto export ----------------------------------------------------------

def test_perfetto_export_schema(tmp_path):
    tr = Tracer()
    with tr.span("prep.pack", t=0):
        pass
    tr.instant("ctl.slots", round=0)
    tr.counter("cache_hit_rate", 0.5)
    tr.add_span("exec.sync", 10.0, 0.25, lane="worker1", wid=1)
    path = str(tmp_path / "trace.json")
    assert write_trace(path, tr.snapshot()) == path
    doc = json.load(open(path))
    evs = doc["traceEvents"]
    metas = [e for e in evs if e["ph"] == "M"]
    assert metas[0] == {"ph": "M", "name": "process_name", "pid": 0,
                        "tid": 0, "args": {"name": "pollen-engine"}}
    lanes = {e["args"]["name"]: e["tid"] for e in metas[1:]}
    assert "worker1" in lanes and len(lanes) == 2
    spans = [e for e in evs if e["ph"] == "X"]
    assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in spans)
    assert {e["name"] for e in spans} == {"prep.pack", "exec.sync"}
    sync = next(e for e in spans if e["name"] == "exec.sync")
    assert sync["tid"] == lanes["worker1"]
    assert sync["dur"] == pytest.approx(0.25e6)     # µs
    insts = [e for e in evs if e["ph"] == "i"]
    assert insts and all(e["s"] == "t" for e in insts)
    ctrs = [e for e in evs if e["ph"] == "C"]
    assert ctrs and ctrs[0]["args"]["value"] == 0.5
    # empty snapshots still produce a loadable document
    assert trace_events([])[0]["name"] == "process_name"


# -- the tentpole invariant ---------------------------------------------------

def test_tracer_on_off_bit_identity_matrix():
    """Acceptance matrix: depths {0,1,2} x shard counts {1,2}, controller
    live (hair-trigger drift + per-worker slot climbing).  The traced run
    must be indistinguishable from the untraced run in every result field
    that feeds training, the schedule, or the SLO report."""
    kw = dict(drift=0.4, adapt=2, granularity="worker")
    for mesh in (0, 2):
        for depth in (0, 1, 2):
            base = _signature(_engine(mesh=mesh, depth=depth, **kw).run(5))
            obs = make_observability(trace_rounds=16)
            traced = _engine(mesh=mesh, depth=depth, obs=obs, **kw)
            got = _signature(traced.run(5))
            tag = f"mesh={mesh} depth={depth}"
            assert got == base, f"tracer perturbed results at {tag}"
            st = obs.tracer.stats()
            assert st["spans"] > 0, f"no spans recorded at {tag}"
            names = {r[1] for r in obs.tracer.snapshot()}
            assert "prep.pack" in names and "exec.wait" in names, names
            if mesh:
                assert "exec.sync" in names, names
                sync_lanes = {r[4] for r in obs.tracer.snapshot()
                              if r[1] == "exec.sync"}
                assert sync_lanes == {f"worker{w}" for w in range(4)}


def test_traced_engine_produces_producer_lane_spans():
    """Pipeline depth 2: producer spans must land on the pollen-pack lane
    and consumer spans on the main thread — the two-track trace is what
    makes the idle-gap visible in Perfetto."""
    obs = make_observability(trace_rounds=16)
    eng = _engine(depth=2, obs=obs)
    eng.run(4)
    by_lane = {}
    for r in obs.tracer.snapshot():
        if r[0] == "X":
            by_lane.setdefault(r[4], []).append(r[1])
    pack_lanes = [ln for ln in by_lane if ln.startswith("pollen-pack")]
    assert pack_lanes, by_lane.keys()
    assert "prep.pack" in by_lane[pack_lanes[0]]
    main = threading.current_thread().name
    assert "exec.wait" in by_lane[main]
    # only the pipeline's one priming prep runs on the consumer thread;
    # every steady-state prep lands on the producer lane
    assert by_lane[main].count("prep.pack") == 1
    assert by_lane[pack_lanes[0]].count("prep.pack") == 3


def test_round_results_report_idle_fraction_and_critical_path():
    res = _engine(mesh=2, depth=1).run(4)
    for r in res:
        assert 0.0 <= r.idle_fraction < 1.0
        assert r.critical_path in ("exec", "pack", "barrier", "combine")
    # deterministic: a rerun reproduces the fractions bit-for-bit
    again = _engine(mesh=2, depth=1).run(4)
    assert [r.idle_fraction for r in res] == \
        [r.idle_fraction for r in again]


# -- flight recorder ----------------------------------------------------------

def test_flight_recorder_retention_is_bounded(tmp_path):
    tr = Tracer()
    fr = FlightRecorder(tr, MetricsRegistry(), rounds=3,
                        path=str(tmp_path / "flight.json"))
    for i in range(10):
        fr.on_round(i, {"loss": float(i)})
    assert fr.dump("unit test") is not None
    doc = json.load(open(fr.path))
    assert [r["round"] for r in doc["rounds"]] == [7, 8, 9]
    assert doc["reason"] == "unit test"
    assert fr.dumps == 1 and fr.last_reason == "unit test"


def test_flight_recorder_dump_never_raises(tmp_path):
    fr = FlightRecorder(Tracer(), path=str(tmp_path / "no" / "\0bad"))
    assert fr.dump("boom") is None      # unwritable path swallowed
    assert fr.dumps == 0


def test_flight_recorder_dumps_on_injected_prep_failure(tmp_path):
    path = str(tmp_path / "flight.json")
    obs = make_observability(trace_rounds=16, flight_rounds=4,
                             flight_path=path)
    eng = _engine(depth=1, obs=obs)
    eng.run(3)

    def boom(t):
        raise RuntimeError("injected prep failure")

    eng.placement.refit = boom
    with pytest.raises(RuntimeError, match="injected prep failure"):
        eng.run(2)
    doc = json.load(open(path))
    assert "abort" in doc["reason"]
    assert "injected prep failure" in doc["reason"]
    assert doc["rounds"], "flight dump lost the retained rounds"
    assert doc["rounds"][-1]["round"] == 2
    assert "critique" in doc["rounds"][-1]
    assert doc["spans"], "flight dump lost the span window"
    assert doc["metrics"]["counters"]["rounds"] == 3
