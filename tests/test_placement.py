"""Placement-strategy tests (paper §4.1/§4.2 + Table 2 semantics)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.placement import (BatchesBasedPlacement, ClientInfo,
                                  LearningBasedPlacement,
                                  RoundRobinPlacement, WorkerInfo,
                                  make_placement)
from repro.core.telemetry import PROFILES, SyntheticTelemetry


def _clients(sizes):
    return [ClientInfo(cid=i, n_batches=int(x)) for i, x in enumerate(sizes)]


def _workers(n, types=None):
    types = types or ["a40"] * n
    return [WorkerInfo(wid=i, type_name=t) for i, t in enumerate(types)]


# ---------------------------------------------------------------------------
# properties every placement must satisfy
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(sizes=st.lists(st.integers(1, 500), min_size=1, max_size=120),
       n_workers=st.integers(1, 9),
       strategy=st.sampled_from(["rr", "bb"]))
def test_partition_property(sizes, n_workers, strategy):
    """Every client is assigned to exactly one worker."""
    placement = make_placement(strategy)
    a = placement.assign(_clients(sizes), _workers(n_workers))
    seen = [c.cid for cs in a.per_worker.values() for c in cs]
    assert sorted(seen) == list(range(len(sizes)))


@settings(max_examples=30, deadline=None)
@given(sizes=st.lists(st.integers(1, 100), min_size=4, max_size=80),
       n_workers=st.integers(2, 8))
def test_rr_count_balance(sizes, n_workers):
    """RR: per-worker client counts differ by at most one (§4.1)."""
    a = RoundRobinPlacement().assign(_clients(sizes), _workers(n_workers))
    counts = [len(cs) for cs in a.per_worker.values()]
    assert max(counts) - min(counts) <= 1


@settings(max_examples=30, deadline=None)
@given(sizes=st.lists(st.integers(1, 400), min_size=8, max_size=100),
       n_workers=st.integers(2, 6))
def test_bb_batch_balance(sizes, n_workers):
    """BB/LPT: load spread bounded by the largest single client."""
    a = BatchesBasedPlacement().assign(_clients(sizes), _workers(n_workers))
    loads = [sum(c.n_batches for c in cs) for cs in a.per_worker.values()]
    assert max(loads) - min(loads) <= max(sizes)


def test_bb_beats_rr_on_skewed_sizes():
    rng = np.random.default_rng(0)
    sizes = np.maximum(1, rng.lognormal(3.5, 1.5, 200).astype(int))
    clients, workers = _clients(sizes), _workers(4)
    def time_of(w, c):
        return float(c.n_batches)
    idle_rr = RoundRobinPlacement().assign(clients, workers).idle_time(time_of)
    idle_bb = BatchesBasedPlacement().assign(clients, workers).idle_time(time_of)
    assert idle_bb < idle_rr


# ---------------------------------------------------------------------------
# learning-based placement (the paper's contribution)
# ---------------------------------------------------------------------------
def _train_lb(lb, workers, rounds=3, n=300, seed=0):
    tel = SyntheticTelemetry(seed=seed)
    rng = np.random.default_rng(seed)
    for r in range(rounds):
        xs = np.maximum(1, rng.lognormal(3.0, 1.2, n).astype(int))
        for w in workers:
            for x in xs[:: len(workers)]:
                lb.observe(r, w, int(x),
                           tel.sample_time(w.type_name, int(x)))
    lb.refit(rounds + 1)


def test_lb_falls_back_to_rr_until_ready():
    lb = LearningBasedPlacement()
    workers = _workers(3)
    a = lb.assign(_clients([5, 9, 2, 7]), workers)
    assert lb.used_fallback
    counts = [len(cs) for cs in a.per_worker.values()]
    assert max(counts) - min(counts) <= 1


def test_lb_beats_rr_and_bb_on_heterogeneous_gpus():
    """Table 2: LB minimizes idle time under GPU heterogeneity, because BB
    cannot see that a 2080 Ti is slower than an A40."""
    workers = _workers(4, ["a40", "2080ti", "2080ti", "2080ti"])
    lb = LearningBasedPlacement()
    _train_lb(lb, workers)
    rng = np.random.default_rng(42)
    sizes = np.maximum(1, rng.lognormal(3.5, 1.3, 400).astype(int))
    clients = _clients(sizes)

    def time_of(wid, c):
        t = {0: "a40", 1: "2080ti", 2: "2080ti", 3: "2080ti"}[wid]
        return float(PROFILES[t].mean_time(c.n_batches))

    idles = {}
    for name, p in [("lb", lb), ("rr", RoundRobinPlacement()),
                    ("bb", BatchesBasedPlacement())]:
        idles[name] = p.assign(clients, workers).idle_time(time_of)
    assert idles["lb"] < idles["rr"]
    assert idles["lb"] < idles["bb"]
    # paper reports 25-50% reduction; require ≥20% here (noise margin)
    assert idles["lb"] < 0.8 * min(idles["rr"], idles["bb"])


def test_lb_orders_fastest_worker_first():
    """§4.2: at the start, the largest client goes to the fastest worker."""
    workers = _workers(2, ["a40", "2080ti"])
    lb = LearningBasedPlacement()
    _train_lb(lb, workers)
    clients = _clients([500, 1, 1, 1])
    a = lb.assign(clients, workers)
    assert not lb.used_fallback
    # worker 0 (a40) must receive the 500-batch client
    assert 0 in [c.cid for c in a.per_worker[0]]
