"""Test-suite bootstrap.

``hypothesis`` is an optional dependency: several suites use it for property
tests, but clean environments (CI base images, the benchmark container) may
not ship it.  Install the deterministic fallback shim under the
``hypothesis`` module name before any test module imports it, so the whole
suite collects and runs either way.
"""

import os
import sys
import types

sys.path.insert(0, os.path.dirname(__file__))

try:
    import hypothesis  # noqa: F401  (real library wins when present)
except ImportError:
    import _hypothesis_stub as _stub

    mod = types.ModuleType("hypothesis")
    mod.given = _stub.given
    mod.settings = _stub.settings
    mod.strategies = _stub.strategies
    mod.__stub__ = True
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "lists", "sampled_from"):
        setattr(st_mod, name, getattr(_stub.strategies, name))
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
