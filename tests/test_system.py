"""End-to-end system behaviour: the composed engine (dataset → sampler →
placement → round step → telemetry → checkpoint) on the paper's tasks,
including fault tolerance and straggler mitigation."""

import jax
import numpy as np
import pytest

from repro.core import (EngineConfig, FederatedEngine, SyntheticTelemetry,
                        UniformSampler, make_placement)
from repro.data import make_federated_dataset
from repro.distributed import FailureEvent, WorkerPool
from repro.fl.strategy import FedMedian
from repro.launch.train import build_engine
from repro.models.papertasks import make_task_model
from repro.optim import sgd


def _small_engine(tmp_path=None, placement="lb", strategy="fedavg",
                  workers=2, rounds_per_ckpt=2, deadline_rho=0.0,
                  pool=None):
    ds = make_federated_dataset("sr", n_clients=64, input_dim=16,
                                batch_size=4, size_mu=2.5, size_sigma=0.8)
    params, loss = make_task_model("sr", jax.random.key(0), input_dim=16,
                                   width=32, n_blocks=2)
    from repro.checkpoint import CheckpointStore
    from repro.fl.strategy import FedAvg
    return FederatedEngine(
        dataset=ds, loss_fn=loss, init_params=params,
        optimizer=sgd(0.1, momentum=0.9),
        placement=make_placement(placement),
        sampler=UniformSampler(64, 8),
        pool=pool or WorkerPool.homogeneous(workers, type_name="a40",
                                            concurrency=2),
        telemetry=SyntheticTelemetry(),
        strategy=FedAvg() if strategy == "fedavg" else FedMedian(),
        config=EngineConfig(steps_cap=4, batch_size=4,
                            rounds_per_checkpoint=rounds_per_ckpt,
                            deadline_rho=deadline_rho),
        checkpoint_store=(CheckpointStore(str(tmp_path)) if tmp_path
                          else None))


def test_training_reduces_loss():
    eng = _small_engine()
    res = eng.run(8)
    assert res[-1].loss < res[0].loss * 0.8
    assert all(np.isfinite(r.loss) for r in res)


def test_lb_switches_from_rr_after_warmup():
    eng = _small_engine(placement="lb")
    eng.run(2)
    assert eng.placement.used_fallback        # warm-up rounds are RR (§4.2)
    eng.run(2)
    assert not eng.placement.used_fallback    # LB takes over from round 3


def test_fedmedian_gather_path():
    eng = _small_engine(strategy="fedmedian")
    res = eng.run(4)
    assert res[-1].loss < res[0].loss * 1.1   # robust agg still trains


def test_checkpoint_resume_is_exact(tmp_path):
    eng1 = _small_engine(tmp_path=tmp_path)
    eng1.run(4)                               # checkpoints at rounds 2, 4
    saved = jax.tree.map(lambda x: np.asarray(x).copy(), eng1.params)

    eng2 = _small_engine(tmp_path=tmp_path)
    assert eng2.restore_latest()
    assert eng2.round_idx == 4
    for a, b in zip(jax.tree.leaves(saved), jax.tree.leaves(eng2.params)):
        np.testing.assert_array_equal(a, np.asarray(b))
    # the LB telemetry resumed warm: model is ready without new warm-up
    res = eng2.run(1)
    assert not eng2.placement.used_fallback
    assert np.isfinite(res[-1].loss)


def test_resumed_synthetic_run_bit_identical(tmp_path):
    """ROADMAP follow-on (c): the synthetic-telemetry RNG stream rides the
    checkpoint (snapshotted at prepare time, like the sampler RNG), so a
    restore-and-resume run re-draws exactly the times — and therefore the
    LB placements and losses — of the uninterrupted run.  The pool is
    heterogeneous so the placement (and thus the losses) actually depends
    on the per-type fits the draws feed."""
    def mixed_pool():
        return WorkerPool.from_specs([("a40", 1.0, 2), ("2080ti", 0.42, 2)])

    whole = _small_engine(tmp_path=tmp_path / "a", pool=mixed_pool())
    ref = whole.run(6)

    eng1 = _small_engine(tmp_path=tmp_path / "b", pool=mixed_pool())
    eng1.run(4)                               # checkpoints at rounds 2, 4
    eng2 = _small_engine(tmp_path=tmp_path / "b", pool=mixed_pool())
    assert eng2.restore_latest()
    assert eng2.round_idx == 4
    resumed = eng2.run(2)                     # rounds 4 and 5
    assert [r.loss for r in resumed] == [r.loss for r in ref[4:]]
    assert [r.makespan for r in resumed] == [r.makespan for r in ref[4:]]
    # the snapshot is prepare-time: depth-1 read-ahead must not leak draws
    assert eng2.telemetry.rng.bit_generator.state != \
        SyntheticTelemetry().rng.bit_generator.state


def test_worker_failure_and_join_mid_training():
    """Node loss: next round's placement simply excludes the worker; a
    joined worker starts receiving clients (one-shot placement elasticity)."""
    eng = _small_engine(workers=3)
    eng.pool.schedule(FailureEvent(round_idx=2, kind="fail", wid=1))
    eng.pool.schedule(FailureEvent(round_idx=4, kind="join", wid=7,
                                   type_name="a40"))
    res = eng.run(6)
    assert len(eng.pool) == 3                 # 3 - 1 + 1
    assert all(np.isfinite(r.loss) for r in res)
    assert 7 in eng.pool.workers


def test_deadline_oversampling_trims_stragglers():
    eng = _small_engine(deadline_rho=0.5)
    res = eng.run(4)
    assert all(r.n_clients == 8 for r in res)  # trimmed back to target


def test_pool_empty_raises():
    pool = WorkerPool.homogeneous(1)
    pool.fail(0)
    with pytest.raises(RuntimeError):
        pool.snapshot()


def test_build_engine_lm_arch_smoke():
    """The train driver composes an assigned LM arch end to end."""
    eng = build_engine(arch="qwen3-0.6b", preset="smoke", cohort=4,
                       workers=2, steps_cap=2)
    res = eng.run(3)
    assert all(np.isfinite(r.loss) for r in res)


def test_build_engine_frontend_arch_smoke():
    eng = build_engine(arch="whisper-base", preset="smoke", cohort=2,
                       workers=1, steps_cap=2)
    res = eng.run(2)
    assert all(np.isfinite(r.loss) for r in res)


def test_s_bucketing_bounds_recompiles():
    from repro.core.engine import s_bucket
    buckets = {s_bucket(s) for s in range(1, 1000)}
    assert len(buckets) <= 16                 # O(log S) distinct shapes
    assert all(s_bucket(s) >= s for s in range(1, 1000))
