"""Per-architecture smoke tests (deliverable f): every assigned arch at a
REDUCED same-family scale runs one forward + one federated train step on CPU
with correct shapes and no NaNs.  The FULL configs are exercised only by the
dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, ARCHS
from repro.fl.round import make_round_step
from repro.models import (decode_step, forward, init_params,
                          make_loss_fn, prefill)
from repro.optim import sgd

KEY = jax.random.key(0)


def _batch(cfg, b=2, s=16, seed=1):
    batch = {"tokens": jax.random.randint(jax.random.fold_in(KEY, seed),
                                          (b, s), 0, cfg.vocab_size)}
    if cfg.frontend == "patch":
        batch["patch_embed"] = jax.random.normal(
            jax.random.fold_in(KEY, seed + 1),
            (b, cfg.frontend_len, cfg.resolved_frontend_dim))
    if cfg.frontend == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(KEY, seed + 1),
            (b, cfg.frontend_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_forward_shapes_and_finite(arch):
    cfg = ARCHS[arch].reduced()
    params = init_params(KEY, cfg)
    b, s = 2, 16
    batch = _batch(cfg, b, s)
    logits = forward(params, batch, cfg)
    s_tot = s + (cfg.frontend_len if cfg.frontend == "patch" else 0)
    assert logits.shape == (b, s_tot, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_federated_train_step(arch):
    """One full federated round (W=2 workers, P=1, S=2 local steps) on the
    reduced config: loss finite, params updated, weights conserved."""
    cfg = ARCHS[arch].reduced()
    params = init_params(KEY, cfg)
    step = jax.jit(make_round_step(make_loss_fn(cfg), sgd(0.05, 0.9)))
    W, P, S, b, s = 2, 1, 2, 2, 16
    batch = _batch(cfg, W * P * S * b, s)
    batches = {k: v.reshape((W, P, S, b) + v.shape[1:])
               for k, v in batch.items()}
    ones = jnp.ones((W, P, S), jnp.float32)
    boundary = jnp.zeros((W, P, S)).at[:, :, -1].set(1.0)
    weight = boundary * 4.0
    new_params, metrics = step(params, batches, ones, boundary, weight)
    assert np.isfinite(float(metrics.loss))
    assert float(metrics.clients) == W * P
    assert float(metrics.total_weight) == W * P * 4.0
    # parameters must actually move
    diff = sum(float(jnp.abs(a.astype(jnp.float32)
                             - b2.astype(jnp.float32)).sum())
               for a, b2 in zip(jax.tree.leaves(new_params),
                                jax.tree.leaves(params)))
    assert diff > 0


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "jamba-v0.1-52b",
                                  "whisper-base", "mamba2-2.7b",
                                  "qwen3-moe-235b-a22b"])
def test_reduced_decode_matches_forward(arch):
    """prefill + 2 decode steps == teacher-forced forward (one family per
    mixer/cache kind)."""
    from dataclasses import replace
    cfg = ARCHS[arch].reduced()
    if cfg.moe:   # droppless reference for capacity-free comparison
        cfg = replace(cfg, capacity_factor=cfg.n_experts / cfg.top_k)
    params = init_params(KEY, cfg)
    b, s = 2, 12
    batch = _batch(cfg, b, s + 2, seed=7)
    toks = batch["tokens"]
    full = forward(params, batch, cfg)
    pre = dict(batch)
    pre["tokens"] = toks[:, :s]
    off = cfg.frontend_len if cfg.frontend == "patch" else 0
    lg, cache = prefill(params, pre, cfg, max_len=off + s + 4)
    np.testing.assert_allclose(
        np.asarray(lg[:, :cfg.vocab_size]), np.asarray(full[:, off + s - 1]),
        rtol=2e-4, atol=2e-4)
    for i in range(2):
        lg, cache = decode_step(params, cache, toks[:, s + i:s + i + 1],
                                jnp.int32(off + s + i), cfg)
        np.testing.assert_allclose(
            np.asarray(lg[:, :cfg.vocab_size]),
            np.asarray(full[:, off + s + i]), rtol=3e-4, atol=3e-4)


def test_masked_steps_are_exact_noops():
    """A padded (masked) local step must leave the round result identical —
    the invariant Pollen's padding-as-idle-time mapping relies on."""
    cfg = ARCHS["qwen3-0.6b"].reduced()
    params = init_params(KEY, cfg)
    step = jax.jit(make_round_step(make_loss_fn(cfg), sgd(0.05, 0.9)))
    W, P, b, s = 1, 1, 2, 16
    batch = _batch(cfg, 2 * b, s, seed=3)
    bt = {k: v.reshape((W, P, 2, b) + v.shape[1:]) for k, v in batch.items()}
    # variant A: S=2 real steps
    ones = jnp.ones((W, P, 2))
    boundary = jnp.zeros((W, P, 2)).at[:, :, 1].set(1.0)
    weight = boundary * 2.0
    pa, _ = step(params, bt, ones, boundary, weight)
    # variant B: S=3 with a masked tail step (garbage data in the pad slot)
    bt3 = {k: jnp.concatenate(
        [v, jnp.ones_like(v[:, :, :1]) * 7], axis=2) for k, v in bt.items()}
    mask3 = jnp.concatenate([ones, jnp.zeros((W, P, 1))], axis=2)
    boundary3 = jnp.concatenate([boundary, jnp.zeros((W, P, 1))], axis=2)
    weight3 = boundary3 * 2.0
    pb, _ = step(params, bt3, mask3, boundary3, weight3)
    for a, b2 in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b2, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_param_counts_match_published_sizes():
    """Full configs instantiate (eval_shape only) to the published sizes."""
    targets = {
        "qwen3-0.6b": 0.6e9, "minitron-4b": 4.2e9, "internlm2-1.8b": 1.9e9,
        "command-r-plus-104b": 104e9, "granite-moe-3b-a800m": 3.3e9,
        "qwen3-moe-235b-a22b": 235e9, "internvl2-26b": 20e9,  # LM backbone
        "jamba-v0.1-52b": 52e9, "whisper-base": 74e6, "mamba2-2.7b": 2.7e9,
    }
    for arch, want in targets.items():
        shapes = jax.eval_shape(lambda k, c=ARCHS[arch]: init_params(k, c),
                                KEY)
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
        assert abs(n - want) / want < 0.12, (arch, n, want)
