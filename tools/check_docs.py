"""Docs consistency checks (run by the CI lint job and tier-1 tests).

Three checks, all zero-dependency beyond the repo itself:

1. **Markdown link check** — every relative link in the repo's markdown
   files must resolve to an existing file (anchors are stripped; http(s)
   and mailto links are not fetched).  Catches renamed/moved docs.
2. **Flag-reference freshness** — the README section between
   ``<!-- flags:begin -->`` / ``<!-- flags:end -->`` must equal the output
   of ``python -m repro.launch.train --print-flags-md`` exactly.  The
   table is generated, never hand-edited, so CLI and docs cannot drift.
3. **Architecture coverage** — ``docs/ARCHITECTURE.md`` must keep naming
   the subsystems and invariants it exists to explain (the needle list
   below); a rename or removed section must update the doc, not orphan
   it.  ``tests/test_docs.py`` asserts the same list in tier-1.

Usage::

    PYTHONPATH=src python tools/check_docs.py
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
MD_FILES = sorted(
    list(REPO.glob("*.md")) + list((REPO / "docs").glob("*.md")))
LINK_RX = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
BEGIN, END = "<!-- flags:begin -->", "<!-- flags:end -->"

# What docs/ARCHITECTURE.md must keep covering (case-insensitive): the
# machine's moving parts and the invariants the test suite enforces.
ARCHITECTURE_NEEDLES = (
    "PRODUCER", "CONSUMER", "PackBuffers", "refit barrier",
    "DriftDetector", "DeviceBatchCache", "WorkerShardMap", "mesh_workers",
    "which module owns which invariant", "bit-identical",
    # the hierarchical-mesh layer (per-worker S buckets, shard-local
    # combine trees, orphan-shard reclamation)
    "Hierarchical combine", "bucket_mode", "combine_mode",
    "make_shard_merge_step", "Orphan-shard reclamation", "rebalance",
    "live_shards", "discard_workers", "combine_bytes",
    # the compressed cross-shard combine (delta wire format, error
    # feedback, fused dequant-merge kernel, checkpointed residuals)
    "Compressed combine", "combine_compress", "error feedback",
    "CombineCompressor", "dequant-merge", "residual_norm",
)


def check_architecture_coverage() -> list[str]:
    doc = (REPO / "docs" / "ARCHITECTURE.md").read_text(encoding="utf-8")
    low = doc.lower()
    return [f"docs/ARCHITECTURE.md: no longer mentions {needle!r}"
            for needle in ARCHITECTURE_NEEDLES if needle.lower() not in low]


def check_links() -> list[str]:
    errors = []
    for md in MD_FILES:
        text = md.read_text(encoding="utf-8")
        for target in LINK_RX.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(REPO)}: broken link "
                              f"-> {target}")
    return errors


def check_flags_section() -> list[str]:
    readme = (REPO / "README.md").read_text(encoding="utf-8")
    if BEGIN not in readme or END not in readme:
        return [f"README.md: missing {BEGIN} / {END} markers"]
    current = readme.split(BEGIN, 1)[1].split(END, 1)[0].strip()
    sys.path.insert(0, str(REPO / "src"))
    from repro.launch.train import flags_markdown
    expected = flags_markdown().strip()
    if current != expected:
        return ["README.md flag reference is stale — regenerate with:\n"
                "  PYTHONPATH=src python -m repro.launch.train "
                "--print-flags-md\nand paste between the flags markers"]
    return []


def main() -> int:
    errors = (check_links() + check_flags_section()
              + check_architecture_coverage())
    for e in errors:
        print(f"FAIL {e}")
    if not errors:
        print(f"docs OK ({len(MD_FILES)} markdown files, links + flag "
              "reference fresh)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
