"""Docs consistency checks (run by the CI lint job and tier-1 tests).

Three checks, all zero-dependency beyond the repo itself:

1. **Markdown link check** — every relative link in the repo's markdown
   files (top level plus everything under ``docs/``, recursively) must
   resolve to an existing file (anchors are stripped; http(s) and mailto
   links are not fetched).  Catches renamed/moved docs.
2. **Flag-reference freshness** — the README section between
   ``<!-- flags:begin -->`` / ``<!-- flags:end -->`` must equal the output
   of ``python -m repro.launch.train --print-flags-md`` exactly.  The
   table is generated, never hand-edited, so CLI and docs cannot drift.
3. **Doc coverage** — each doc in ``DOC_NEEDLES`` must keep naming the
   subsystems and invariants it exists to explain; a rename or removed
   section must update the doc, not orphan it.  ``tests/test_docs.py``
   asserts the same lists in tier-1.

Usage::

    PYTHONPATH=src python tools/check_docs.py
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
MD_FILES = sorted(
    list(REPO.glob("*.md")) + list((REPO / "docs").rglob("*.md")))
LINK_RX = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
BEGIN, END = "<!-- flags:begin -->", "<!-- flags:end -->"

# What docs/ARCHITECTURE.md must keep covering (case-insensitive): the
# machine's moving parts and the invariants the test suite enforces.
ARCHITECTURE_NEEDLES = (
    "PRODUCER", "CONSUMER", "PackBuffers", "refit barrier",
    "DriftDetector", "DeviceBatchCache", "WorkerShardMap", "mesh_workers",
    "which module owns which invariant", "bit-identical",
    # the hierarchical-mesh layer (per-worker S buckets, shard-local
    # combine trees, orphan-shard reclamation)
    "Hierarchical combine", "bucket_mode", "combine_mode",
    "make_shard_merge_step", "Orphan-shard reclamation", "rebalance",
    "live_shards", "discard_workers", "combine_bytes",
    # the compressed cross-shard combine (delta wire format, error
    # feedback, fused dequant-merge kernel, checkpointed residuals)
    "Compressed combine", "combine_compress", "error feedback",
    "CombineCompressor", "dequant-merge", "residual_norm",
    # the open-world population layer (streaming registry + SLO metrics)
    "Open-world population", "OnlinePoolSampler", "ArrivalIndex",
    "stale_fraction", "never materializes",
    # the observability plane (tracer bit-identity, idle-gap accounting,
    # flight dumps) and controller checkpoint persistence
    "Tracer", "idle_fraction", "flight recorder", "state_dict",
    # the host hierarchy (shard→host partition, canonical pairwise tree,
    # process-per-host harness, sidecar telemetry replay)
    "Host hierarchy", "HostShardMap", "pairwise_reduce",
    "launch.multihost", "SidecarChannel", "host_layout",
    "exec.host_merge", "O(hosts)",
)

# What docs/OBSERVABILITY.md must keep covering: the tracer's ring
# mechanics and the no-perturbation invariant, the span taxonomy, the
# idle-gap formula, the Perfetto workflow, the flight-recorder dump
# triggers, and the overhead/trend gates.
OBSERVABILITY_NEEDLES = (
    "Tracer", "MetricsRegistry", "FlightRecorder", "make_observability",
    "NULL_TRACER", "bit-identical", "overwrite-oldest",
    "prep.pack", "prep.barrier", "exec.wait", "exec.sync", "pollen-pack",
    "critique_round", "idle_time / (makespan * n_workers)",
    "critical_path", "write_trace", "ui.perfetto.dev", "--trace-out",
    "--flight-rounds", "SIGTERM", "never to raise",
    "tracer_overhead_fraction", "trend_summary.json",
    "state_dict", ".aux.npz", "exec.host_merge",
)

# What docs/POPULATION.md must keep covering: the registry's hash streams,
# the arrival model, the streaming sampler's lifecycle and checkpoint
# story, the SLO metric definitions, and the full scenario-storm catalog.
POPULATION_NEEDLES = (
    "ClientMetadataStore", "ArrivalIndex", "OnlinePoolSampler",
    "PopulationDataset", "splitmix64", "diurnal", "rejection",
    "stale_fraction", "slo_p50", "slo_p99", "online_pool",
    "expected_online", "sampler_state", "never materializes",
    "storm catalog", "surge", "outage", "straggler", "fail", "skew",
    "adapt",
)

# doc path (relative to the repo root) -> needles it must keep naming
DOC_NEEDLES = {
    "docs/ARCHITECTURE.md": ARCHITECTURE_NEEDLES,
    "docs/POPULATION.md": POPULATION_NEEDLES,
    "docs/OBSERVABILITY.md": OBSERVABILITY_NEEDLES,
}


def check_doc_coverage() -> list[str]:
    errors = []
    for rel, needles in DOC_NEEDLES.items():
        path = REPO / rel
        if not path.exists():
            errors.append(f"{rel}: missing (coverage-enforced doc)")
            continue
        low = path.read_text(encoding="utf-8").lower()
        errors.extend(f"{rel}: no longer mentions {needle!r}"
                      for needle in needles if needle.lower() not in low)
    return errors


def check_architecture_coverage() -> list[str]:
    return [e for e in check_doc_coverage()
            if e.startswith("docs/ARCHITECTURE.md")]


def check_links() -> list[str]:
    errors = []
    for md in MD_FILES:
        text = md.read_text(encoding="utf-8")
        for target in LINK_RX.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(REPO)}: broken link "
                              f"-> {target}")
    return errors


def check_flags_section() -> list[str]:
    readme = (REPO / "README.md").read_text(encoding="utf-8")
    if BEGIN not in readme or END not in readme:
        return [f"README.md: missing {BEGIN} / {END} markers"]
    current = readme.split(BEGIN, 1)[1].split(END, 1)[0].strip()
    sys.path.insert(0, str(REPO / "src"))
    from repro.launch.train import flags_markdown
    expected = flags_markdown().strip()
    if current != expected:
        return ["README.md flag reference is stale — regenerate with:\n"
                "  PYTHONPATH=src python -m repro.launch.train "
                "--print-flags-md\nand paste between the flags markers"]
    return []


def main() -> int:
    errors = (check_links() + check_flags_section()
              + check_doc_coverage())
    for e in errors:
        print(f"FAIL {e}")
    if not errors:
        print(f"docs OK ({len(MD_FILES)} markdown files, links + flag "
              "reference fresh)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
