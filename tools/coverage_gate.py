"""CI coverage gate: line coverage of the gated subsystems must not drop.

The tier-1 suite runs under ``coverage run`` in CI; this tool reads the
``coverage json`` report and fails the build when any gated package's
line coverage falls below the committed baseline
(``tools/coverage_baseline.json``) by more than the slack.  The gate is
*ratcheted by hand*: the baseline holds conservative floors, and a PR
that meaningfully raises coverage should also raise them (``--update``
rewrites the baseline from a fresh report, rounded DOWN to whole
percents so run-to-run jitter never trips the gate).

Gated packages — the subsystems whose behavior is mostly reachable only
through engine integration, where a silent test deletion or an
accidentally-skipped suite would otherwise go unnoticed::

    src/repro/control  src/repro/obs  src/repro/population  src/repro/compress

Graceful degradation: environments without the ``coverage`` package (the
benchmark container, local dev boxes) can't produce a report — when the
report file is absent the gate prints a skip notice and exits 0, so the
same make target works everywhere.  CI always installs ``coverage`` and
passes ``--require``, which turns a missing report into a failure.

Usage::

    coverage run --source=src/repro -m pytest -x -q
    coverage json -o coverage.json
    python tools/coverage_gate.py coverage.json            # gate
    python tools/coverage_gate.py coverage.json --update   # ratchet
"""

from __future__ import annotations

import argparse
import json
import os
import sys

GATED_PACKAGES = (
    "src/repro/control",
    "src/repro/obs",
    "src/repro/population",
    "src/repro/compress",
)

DEFAULT_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "coverage_baseline.json")

# Absolute percentage points a package may dip below its floor before the
# gate trips: absorbs platform-conditional lines (e.g. fallback branches)
# without letting a deleted test module (tens of points) through.
SLACK_PCT = 1.0


def package_coverage(report: dict) -> dict[str, dict]:
    """Aggregate a ``coverage json`` report to per-gated-package totals.

    Returns ``{package: {"percent": float, "statements": int,
    "covered": int, "files": int}}``.  File paths are normalised so the
    report may use absolute or repo-relative paths.
    """
    out = {p: {"statements": 0, "covered": 0, "files": 0}
           for p in GATED_PACKAGES}
    for path, entry in (report.get("files") or {}).items():
        norm = path.replace(os.sep, "/")
        # tolerate absolute paths and reports generated from src/ cwd
        idx = norm.find("src/repro/")
        key = norm[idx:] if idx >= 0 else "src/repro/" + norm.lstrip("./")
        for pkg in GATED_PACKAGES:
            if key.startswith(pkg + "/") or key == pkg + ".py":
                s = entry.get("summary", {})
                out[pkg]["statements"] += int(s.get("num_statements", 0))
                out[pkg]["covered"] += int(s.get("covered_lines", 0))
                out[pkg]["files"] += 1
                break
    for pkg, agg in out.items():
        agg["percent"] = (100.0 * agg["covered"] / agg["statements"]
                          if agg["statements"] else 0.0)
    return out


def compare(baseline: dict, fresh: dict, *, slack: float = SLACK_PCT
            ) -> list[str]:
    """Return one message per violation (empty == the gate passes)."""
    failures = []
    for pkg in GATED_PACKAGES:
        floor = baseline.get(pkg)
        if floor is None:
            failures.append(f"baseline has no floor for {pkg} — run "
                            f"--update to (re)generate it")
            continue
        got = fresh.get(pkg, {})
        if not got.get("files"):
            failures.append(f"{pkg}: no files in the coverage report — "
                            f"was the suite run with --source=src/repro?")
            continue
        pct = got["percent"]
        if pct < float(floor) - slack:
            failures.append(
                f"{pkg}: line coverage {pct:.1f}% fell below the committed "
                f"floor {floor:.1f}% (slack {slack}pt) — tests were lost "
                f"or the new code is untested")
    return failures


def update_baseline(fresh: dict) -> dict:
    """Floors from a fresh report, rounded DOWN to whole percents."""
    return {pkg: float(int(fresh[pkg]["percent"])) for pkg in GATED_PACKAGES}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("report", help="coverage json report path")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline floors from this report")
    ap.add_argument("--require", action="store_true",
                    help="fail (instead of skip) when the report is missing")
    args = ap.parse_args(argv)

    if not os.path.exists(args.report):
        if args.require:
            print(f"coverage gate: report {args.report!r} is missing")
            return 1
        print(f"coverage gate: no report at {args.report!r} (coverage not "
              f"installed?) — skipping")
        return 0

    with open(args.report) as f:
        fresh = package_coverage(json.load(f))

    if args.update:
        floors = update_baseline(fresh)
        with open(args.baseline, "w") as f:
            json.dump(floors, f, indent=1, sort_keys=True)
            f.write("\n")
        for pkg, floor in sorted(floors.items()):
            print(f"coverage gate: floor {pkg} = {floor:.0f}%")
        return 0

    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = compare(baseline, fresh)
    for pkg in GATED_PACKAGES:
        agg = fresh[pkg]
        print(f"coverage gate: {pkg}: {agg['percent']:.1f}% "
              f"({agg['covered']}/{agg['statements']} lines, "
              f"{agg['files']} files; floor {baseline.get(pkg, '—')})")
    for msg in failures:
        print("FAIL:", msg)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
